"""Static analysis tests (ISSUE 8): the `repro.netgen.analysis`
invariant layer — structural verifier, interval/range dataflow, plan
certification, tile legality, stack diagnosis, and the ArtifactStore
linter — plus its wiring through `PipelineSpec.run(verify=True)`, the
Session compile driver, the tuner, and the Verilog backend.

Acceptance spine: a deliberately-corrupting pass is caught at the pass
boundary with a diagnostic naming the pass and the node, across three
invariant classes (structural, range/overflow, plan legality); the
tuner skips statically illegal candidates without changing the winner;
artifacts persist and reload their proof summary.
"""
import dataclasses
import json

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import quantize
from repro import netgen
from repro.netgen import analysis
from repro.netgen.analysis import (
    FUSEDNET_VMEM_BYTES, INT32_MAX, Diagnostic, RangeAnalysis,
    VerificationError, analyze_ranges, check_ranges, diagnose_stack,
    effective_tiles, fusednet_vmem_bytes, lint_store, proof_summary,
    summary_row, tile_legality, verify_circuit, verify_plan,
)
from repro.netgen.graph import (
    InputCompare, Term, WeightedSum, node_widths, signed_width,
    value_bounds,
)
from repro.netgen.pipeline import PipelineSpec
from repro.netgen.plan import lower_circuit
from repro.netgen.tune import KernelTuner

from _netgen_helpers import images, random_net

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_stub import given, settings, strategies as st


def _random_net(seed: int, sizes=(12, 9, 4), lo=-5, hi=5):
    return random_net(seed, sizes, lo=lo, hi=hi)


def _images(seed: int, b: int, n_in: int) -> np.ndarray:
    return images(seed, b, n_in, salt=88)


def _ref(net, x):
    return np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))


def _optimized(seed: int, sizes=(12, 9, 4)):
    c = netgen.lower(_random_net(seed, sizes))
    c, _ = PipelineSpec.parse("zeros,prune").run(c, verify=True)
    return c


# ---------------------------------------------------------------------------
# Deliberately-corrupting passes (module-level: the spec round-trips
# them via their dotted name, so the diagnostic's stage names the pass)
# ---------------------------------------------------------------------------

def drop_used_bit(circuit):
    """Corruption, structural class: deletes an InputCompare that a
    WeightedSum still reads — the survivor dangles."""
    used = {t.src for n in circuit.nodes
            if isinstance(n, WeightedSum) for t in n.terms}
    keep, dropped = [], False
    for n in circuit.nodes:
        if not dropped and isinstance(n, InputCompare) and n.id in used:
            dropped = True
            continue
        keep.append(n)
    assert dropped
    return dataclasses.replace(circuit, nodes=tuple(keep))


def triple_final_weights(circuit):
    """Corruption, range class: scales the output-layer weights 3x —
    structurally fine, but the class score envelope widens, which an
    exact rewrite must never do."""
    out = circuit.node(circuit.output)
    finals = set(out.srcs)
    nodes = []
    for n in circuit.nodes:
        if isinstance(n, WeightedSum) and n.id in finals:
            n = dataclasses.replace(n, terms=tuple(
                Term(t.weight * 3, t.src) for t in n.terms))
        nodes.append(n)
    return dataclasses.replace(circuit, nodes=tuple(nodes))


def test_pipeline_catches_structural_corruption():
    c = netgen.lower(_random_net(0))
    spec = PipelineSpec.coerce([netgen.delete_zero_terms, drop_used_bit])
    with pytest.raises(VerificationError) as ei:
        spec.run(c, verify=True)
    diags = ei.value.diagnostics
    assert any(d.check == "structure.topo-order" for d in diags)
    d = next(d for d in diags if d.check == "structure.topo-order")
    assert d.node is not None                  # names the orphaned reader
    assert "drop_used_bit" in d.stage          # names the offending pass
    assert "drop_used_bit" in str(ei.value)


def test_pipeline_catches_envelope_widening():
    c = netgen.lower(_random_net(1))
    spec = PipelineSpec.coerce([triple_final_weights])
    with pytest.raises(VerificationError) as ei:
        spec.run(c, verify=True)
    diags = ei.value.diagnostics
    assert all(d.check == "range.envelope" for d in diags)
    assert "triple_final_weights" in diags[0].stage
    assert "widened" in diags[0].message


def test_pipeline_verify_off_lets_corruption_through():
    # prod posture: the same broken pipeline completes (the Session
    # driver's pre-backend analysis is the backstop there)
    c = netgen.lower(_random_net(1))
    spec = PipelineSpec.coerce([triple_final_weights])
    out, _ = spec.run(c, verify=False)
    assert isinstance(out, type(c))


def test_verify_default_follows_env(monkeypatch):
    c = netgen.lower(_random_net(1))
    spec = PipelineSpec.coerce([triple_final_weights])
    monkeypatch.setenv("NETGEN_VERIFY", "1")
    with pytest.raises(VerificationError):
        spec.run(c)
    monkeypatch.setenv("NETGEN_VERIFY", "0")
    spec.run(c)


# ---------------------------------------------------------------------------
# Structural verifier + postconditions (unit level)
# ---------------------------------------------------------------------------

def test_verifier_clean_on_every_default_stage():
    c = netgen.lower(_random_net(2))
    assert verify_circuit(c, stage="lowered") == []
    for spec in ("zeros", "zeros,prune", "zeros,prune,addends", "hw"):
        out, _ = PipelineSpec.coerce(spec).run(
            netgen.lower(_random_net(2)), verify=True)
        assert verify_circuit(out) == []


def test_verifier_flags_duplicate_id_and_bad_output():
    c = netgen.lower(_random_net(3))
    dup = dataclasses.replace(c, nodes=c.nodes + (c.nodes[0],))
    checks = {d.check for d in verify_circuit(dup, collect=True)}
    assert "structure.duplicate-id" in checks
    noout = dataclasses.replace(c, output=c.nodes[0].id)
    checks = {d.check for d in verify_circuit(noout, collect=True)}
    assert "structure.output" in checks


def test_postconditions_catch_surviving_work():
    c = netgen.lower(_random_net(4))   # unoptimized: has zero weights
    assert any(t.weight == 0 for n in c.nodes
               if isinstance(n, WeightedSum) for t in n.terms)
    diags = verify_circuit(c, after_pass="zeros", collect=True)
    assert any(d.check == "postcondition.zeros" for d in diags)
    diags = verify_circuit(c, after_pass="addend_rewrite", collect=True)
    assert any(d.check == "postcondition.addends" for d in diags)
    # the real passes discharge their own postconditions
    z = netgen.delete_zero_terms(c)
    assert verify_circuit(z, after_pass="zeros") == []
    a = netgen.addend_rewrite(z)
    assert verify_circuit(a, after_pass="addends") == []


# ---------------------------------------------------------------------------
# Range dataflow: parity, proofs, and width edge cases
# ---------------------------------------------------------------------------

def test_ranges_reproduce_value_bounds_and_node_widths():
    for seed in (5, 6):
        c = _optimized(seed)
        ra = analyze_ranges(c)
        assert ra.bounds() == value_bounds(c)
        assert ra.widths() == node_widths(c)
        assert check_ranges(c, ra) == []


def test_zero_weight_layer_edges():
    w1 = np.zeros((4, 3), dtype=np.int32)
    w2 = np.array([[2, -1], [0, 3], [-2, 2]], dtype=np.int32)
    net = quantize.QuantizedNet(w1=w1, w2=w2)
    c = netgen.lower(net)
    ra = analyze_ranges(c)
    hidden = [n for n in c.nodes
              if isinstance(n, WeightedSum) and n.layer == 1]
    for n in hidden:
        r = ra[n.id]
        assert (r.lo, r.hi, r.bound) == (0, 0, 0)
        assert r.width == signed_width(0) >= 1
    # the full pipeline stays verifiable and exact on the degenerate net
    out, _ = PipelineSpec.parse("zeros,prune").run(c, verify=True)
    x = _images(0, 6, 4)
    analysis.check_observed(out, x)
    np.testing.assert_array_equal(netgen.evaluate(out, x), _ref(net, x))


def test_all_negative_weight_layer_has_zero_hi():
    w1 = -np.abs(np.arange(1, 13).reshape(4, 3)).astype(np.int32)
    w2 = np.array([[1, -2], [-3, 1], [2, 2]], dtype=np.int32)
    net = quantize.QuantizedNet(w1=w1, w2=w2)
    c = netgen.lower(net)
    ra = analyze_ranges(c)
    for n in c.nodes:
        if isinstance(n, WeightedSum) and n.layer == 1:
            r = ra[n.id]
            assert r.hi == 0 and r.lo == -r.bound < 0
            # interval is strictly tighter than the symmetric bound
            assert r.max_abs == r.bound
    analysis.check_observed(c, _images(1, 6, 4), ranges=ra)


def test_fan_in_one_signed_width_boundary():
    # a single +w term reaches hi == 2^(width-1) - 1 exactly: the
    # tightest value signed_width's symmetric sizing admits
    w1 = np.array([[3, -3]], dtype=np.int32)
    w2 = np.array([[1, -1], [-1, 1]], dtype=np.int32)
    c = netgen.lower(quantize.QuantizedNet(w1=w1, w2=w2))
    ra = analyze_ranges(c)
    pos = [ra[n.id] for n in c.nodes
           if isinstance(n, WeightedSum) and n.layer == 1
           and n.terms[0].weight > 0]
    assert pos and pos[0].hi == (1 << (pos[0].width - 1)) - 1
    assert check_ranges(c, ra) == []


def test_check_ranges_flags_tampered_width_and_int32():
    c = _optimized(7)
    ra = analyze_ranges(c)
    sid = next(n.id for n in c.nodes
               if isinstance(n, WeightedSum) and ra[n.id].hi > 0)
    r = ra[sid]
    tampered = RangeAnalysis({**ra.ranges, sid: dataclasses.replace(
        r, width=1)})
    checks = {d.check for d in check_ranges(c, tampered, collect=True)}
    assert "range.width-overflow" in checks
    huge = RangeAnalysis({**ra.ranges, sid: dataclasses.replace(
        r, bound=INT32_MAX + 1)})
    checks = {d.check for d in check_ranges(c, huge, collect=True)}
    assert "range.int32" in checks


def test_check_observed_brackets_and_detects_escape():
    c = _optimized(8)
    x = _images(2, 16, 12)
    analysis.check_observed(c, x)           # interpreter stays inside
    ra = analyze_ranges(c)
    sid = next(n.id for n in c.nodes
               if isinstance(n, WeightedSum) and ra[n.id].hi > 0)
    shrunk = RangeAnalysis({**ra.ranges, sid: dataclasses.replace(
        ra[sid], lo=0, hi=0)})
    with pytest.raises(VerificationError, match="range.observed"):
        analysis.check_observed(c, x, ranges=shrunk)


def test_proof_summary_certifies_the_circuit():
    c = _optimized(9)
    s = proof_summary(c)
    assert s["format"] == "netgen-analysis-v1" and s["verified"]
    assert s["sum_nodes"] == sum(
        isinstance(n, WeightedSum) for n in c.nodes)
    assert s["max_width"] == max(
        r.width for r in analyze_ranges(c).ranges.values())
    assert s["int32_safe"] is True and s["slack_bits"] >= 0
    assert "proved" in summary_row(s)


# ---------------------------------------------------------------------------
# Property: random nets x pipelines verify, intervals bracket execution
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       spec=st.sampled_from(
           ["zeros", "zeros,prune", "zeros,prune,addends", "hw"]))
def test_property_pipelines_verify_and_bracket(seed, spec):
    net = _random_net(seed, sizes=(10, 8, 4))
    c = netgen.lower(net)
    stages = []
    out, _ = PipelineSpec.coerce(spec).run(
        c, verify=True, observe=lambda name, cc: stages.append(cc))
    x = _images(seed, 8, 10)
    for cc in stages:
        analysis.check_observed(cc, x)
    np.testing.assert_array_equal(netgen.evaluate(out, x), _ref(net, x))


# ---------------------------------------------------------------------------
# Plan certification
# ---------------------------------------------------------------------------

def test_verify_plan_clean_on_all_forms():
    c = _optimized(10, sizes=(20, 16, 4))
    for form in ("dense", "packed", "planes"):
        plan = lower_circuit(c, form=form)
        assert plan.verify() == []


def test_verify_plan_catches_pad_and_plane_corruption():
    c = _optimized(11, sizes=(20, 16, 4))
    packed = lower_circuit(c, form="packed")
    layer = packed.layers[0]
    w = layer.weights.copy()
    assert w.shape[0] > 20                 # 20 inputs pad to 32 lanes
    w[-1, 0] = 1                           # poison a zero-pad row
    bad = dataclasses.replace(
        packed, layers=(dataclasses.replace(layer, weights=w),)
        + packed.layers[1:])
    checks = {d.check for d in verify_plan(bad, collect=True)}
    assert "plan.pad-exact" in checks

    planes = lower_circuit(c, form="planes")
    layer = planes.layers[0]
    pos = layer.pos_planes.copy()
    pos[0, 0, 0] ^= np.uint32(1)           # flip one decomposed bit
    bad = dataclasses.replace(
        planes, layers=(dataclasses.replace(layer, pos_planes=pos),)
        + planes.layers[1:])
    checks = {d.check for d in verify_plan(bad, collect=True)}
    assert checks & {"plan.planes-lossless", "plan.planes-disjoint"}


def test_verify_plan_catches_broken_chain():
    c = _optimized(12, sizes=(20, 16, 4))
    plan = lower_circuit(c, form="dense")
    bad = dataclasses.replace(plan, layers=plan.layers[1:])
    checks = {d.check for d in verify_plan(bad, collect=True)}
    assert "plan.chain" in checks
    with pytest.raises(VerificationError, match="plan.chain"):
        verify_plan(bad)


# ---------------------------------------------------------------------------
# Tile legality through the tuner
# ---------------------------------------------------------------------------

def _grid():
    return [{"bm": bm, "bn": bn, "bkw": bkw}
            for bm in (8, 64) for bn in (8, 64) for bkw in (1, 8)]


def test_tuner_legality_skips_duplicates_same_winner():
    c = _optimized(13, sizes=(20, 16, 4))
    plan = lower_circuit(c, form="packed")
    batch = 4
    cands = _grid()

    def make_measure(calls):
        def measure(cand):
            eff = effective_tiles(plan, "packed", cand, batch)
            calls.append(eff)
            # deterministic: cost is a pure function of what actually runs
            return 1e-3 + 1e-4 * sum(sum(t) for t in eff)
        return measure

    full_calls, filt_calls = [], []
    fields = {"target": "t", "device_kind": "cpu", "candidates": cands}
    full = KernelTuner().get_or_tune(
        fields, cands, make_measure(full_calls), reps=1)
    tuner = KernelTuner()
    filtered = tuner.get_or_tune(
        fields, cands, make_measure(filt_calls), reps=1,
        legal=tile_legality(plan, batch=batch))
    # every candidate clamps: batch 4 -> bm 8, 20 inputs -> 1 lane word
    assert len(filt_calls) < len(full_calls)
    assert filtered == full                      # same winner, fewer runs
    assert tuner.stats.rejected > 0
    assert tuner.stats.measurements == len(filt_calls) // 2


def test_tuner_all_candidates_illegal_raises():
    c = _optimized(13, sizes=(20, 16, 4))
    plan = lower_circuit(c, form="packed")
    cands = [{"bm": 0, "bn": 8, "bkw": 1}, {"bm": -8, "bn": 8, "bkw": 1}]
    with pytest.raises(ValueError, match="statically illegal"):
        KernelTuner().get_or_tune(
            {"target": "t", "device_kind": "cpu", "candidates": cands},
            cands, lambda c: 0.0,
            legal=tile_legality(plan, batch=4))


def test_tile_legality_keeps_partial_and_distinct_candidates():
    c = _optimized(14, sizes=(40, 16, 4))
    plan = lower_circuit(c, form="dense")
    legal = tile_legality(plan, batch=64)
    assert legal({"bm": 8, "bn": 8, "bkw": 1}) is None
    assert legal({"bm": 16, "bn": 8, "bkw": 1}) is None   # distinct tiles
    assert "duplicate" in legal({"bm": 8, "bn": 8, "bkw": 1})
    assert legal({"form": "dense"}) is None               # partial: keep


def test_fusednet_vmem_matches_view_estimate():
    """The analytic per-candidate estimate (no plane decomposition
    materialized) must agree with what the megakernel view actually
    keeps resident — otherwise the tuner's VMEM gate drifts from the
    kernel it is gating."""
    for seed, sizes in ((17, (45, 21, 7)), (18, (64, 33, 10))):
        plan = lower_circuit(_optimized(seed, sizes=sizes))
        view = plan.planes().megakernel_view()
        for bm, bkw in ((8, 1), (32, 4), (256, 16)):
            assert fusednet_vmem_bytes(plan, bm=bm, bkw=bkw) \
                == view.vmem_bytes(bm=bm, bkw=bkw), (sizes, bm, bkw)


def test_fusednet_candidate_over_vmem_budget_rejected():
    """A batch tile that would not fit the megakernel's whole residency
    in VMEM is rejected BEFORE measurement, with the budget named."""
    plan = lower_circuit(_optimized(19, sizes=(784, 500, 10)))
    legal = tile_legality(plan, batch=4096)
    big = {"form": "fusednet", "bm": 2048, "bn": 8, "bkw": 16}
    reason = legal(big)
    assert reason is not None and "VMEM budget" in reason
    assert fusednet_vmem_bytes(plan, bm=2048, bkw=16, batch=4096) \
        > FUSEDNET_VMEM_BYTES
    small = {"form": "fusednet", "bm": 32, "bn": 8, "bkw": 8}
    assert legal(small) is None


def test_fusednet_bn_only_candidates_dedupe():
    """The megakernel has no fan-out tiling: candidates differing only
    in `bn` clamp to the identical kernel, so the second is rejected as
    a duplicate measurement."""
    plan = lower_circuit(_optimized(19, sizes=(40, 16, 4)))
    a = {"form": "fusednet", "bm": 8, "bn": 8, "bkw": 1}
    b = {"form": "fusednet", "bm": 8, "bn": 64, "bkw": 1}
    eff = effective_tiles(plan, "fusednet", a, 4)
    assert eff == effective_tiles(plan, "fusednet", b, 4)
    assert all(len(t) == 2 for t in eff)    # (bm, bkw) pairs, no bn
    legal = tile_legality(plan, batch=4)
    assert legal(a) is None
    assert "duplicate" in legal(b)


# ---------------------------------------------------------------------------
# Stack diagnosis
# ---------------------------------------------------------------------------

def test_diagnose_stack_axes():
    twins = [netgen.lower(_random_net(s)) for s in (20, 21)]
    rep = diagnose_stack(twins)
    assert rep.compatible and rep.reason == "none"
    assert "stack-compatible" in rep.describe()

    odd = diagnose_stack(twins + [netgen.lower(_random_net(22, (12, 9, 5)))])
    assert not odd.compatible and odd.reason == "stack.classes"
    assert "class count" in odd.describe()

    shared, _ = PipelineSpec.coerce("hw").run(netgen.lower(_random_net(23)))
    rep = diagnose_stack([shared])
    assert not rep.compatible and rep.reason == "stack.irregular"

    packed = lower_circuit(_optimized(24), form="packed")
    rep = diagnose_stack([packed])
    assert not rep.compatible and rep.reason == "stack.form"

    assert diagnose_stack([]).reason == "stack.empty"


def test_netserver_stack_report_on_incompatible_versions():
    server = netgen.NetServer(slot_capacity=8)
    server.register("a", _random_net(25))
    server.register("b", _random_net(26, (12, 9, 5)))   # class mismatch
    x = _images(3, 4, 12)
    out = server.predict_many({"a": x, "b": x})
    assert server.dispatch_counts["fallback"] >= 1
    reports = server.stack_report()
    assert reports, "incompatible stack must leave a structured report"
    rep = next(iter(reports.values()))
    assert not rep.compatible and rep.reason == "stack.classes"
    # per-version answers stay exact through the fallback
    np.testing.assert_array_equal(out["a"], _ref(_random_net(25), x))


# ---------------------------------------------------------------------------
# Session wiring: proof summary persists, widths come from the analysis
# ---------------------------------------------------------------------------

def test_artifact_persists_and_reloads_proof_summary(tmp_path):
    store_dir = tmp_path / "s"
    net = _random_net(30)
    art = netgen.Session(store=netgen.ArtifactStore(store_dir)).compile(
        net, target="jnp")
    assert art.analysis is not None
    assert art.analysis["format"] == "netgen-analysis-v1"
    assert art.analysis["verified"] and art.analysis["int32_safe"]
    assert art.timings["analysis_s"] >= 0
    assert summary_row(art.analysis) in art.report()
    with open(store_dir / art.key / "meta.json") as f:
        assert json.load(f)["analysis"] == art.analysis
    # a cold session reloads the identical certificate from disk
    cold = netgen.Session(store=netgen.ArtifactStore(store_dir)).compile(
        net, target="jnp")
    assert cold.analysis == art.analysis


def test_verilog_widths_come_from_shared_analysis():
    from repro.netgen.backends.verilog import emit_verilog
    c = _optimized(31)
    precomputed = emit_verilog(c, _analysis=analyze_ranges(c))
    assert precomputed == emit_verilog(c)
    # accumulator declarations are sized from NodeRange.width
    widths = analyze_ranges(c).widths()
    some_sum = next(n for n in c.nodes if isinstance(n, WeightedSum))
    assert f"[{widths[some_sum.id] - 1}:0]" in precomputed


def test_strict_compile_raises_on_corrupt_pipeline(tmp_path, monkeypatch):
    monkeypatch.setenv("NETGEN_VERIFY", "0")   # pass boundary check off...
    session = netgen.Session(store=netgen.ArtifactStore(tmp_path / "s"))
    session.compile(_random_net(32), target="jnp",
                    pipeline=[triple_final_weights])   # ...prod proceeds
    monkeypatch.setenv("NETGEN_VERIFY", "1")
    strict = netgen.Session(store=netgen.ArtifactStore(tmp_path / "s2"))
    with pytest.raises(VerificationError):
        # strict: the driver's own pre-backend analysis still catches a
        # value-changing pipeline even though per-pass checks are the
        # pipeline's (the envelope widening shows as a range violation
        # only across passes; structural corruption is caught here)
        strict.compile(
            _random_net(33), target="jnp", pipeline=[drop_used_bit])
    # the raised compile is a counted failure, keeping the cache-tier
    # telemetry identity (misses == compiles + store_hits + failures)
    # intact for the CI metrics gate
    st = strict.stats()
    assert st.failures == 1
    assert st.misses == st.compiles + st.store_hits + st.failures


# ---------------------------------------------------------------------------
# Store linting + CLI
# ---------------------------------------------------------------------------

def _build_store(tmp_path, n=2):
    store_dir = tmp_path / "store"
    session = netgen.Session(store=netgen.ArtifactStore(store_dir))
    for s in range(n):
        session.compile(_random_net(40 + s), target="jnp")
    return store_dir


def test_lint_store_clean_then_corrupted(tmp_path):
    store_dir = _build_store(tmp_path)
    assert lint_store(store_dir) == {}

    entries = sorted(p for p in store_dir.iterdir() if p.is_dir())
    # corrupt a stored cost: recompute disagrees
    meta_path = entries[0] / "meta.json"
    meta = json.loads(meta_path.read_text())
    meta["cost"]["total"] = meta["cost"]["total"] + 7
    meta_path.write_text(json.dumps(meta))
    # stale content address: rename an entry to a key it cannot hash to
    stale = entries[1].with_name("0" * len(entries[1].name))
    entries[1].rename(stale)

    failures = lint_store(store_dir)
    assert set(failures) == {entries[0].name, stale.name}
    assert any(d.check == "store.cost" for d in failures[entries[0].name])
    assert any(d.check == "store.key" for d in failures[stale.name])


def test_lint_store_unreadable_artifacts(tmp_path):
    store_dir = _build_store(tmp_path, n=1)
    entry = next(p for p in store_dir.iterdir() if p.is_dir())
    (entry / "circuit.npz").write_bytes(b"not a zipfile")
    failures = lint_store(store_dir)
    assert any(d.check == "store.circuit" for d in failures[entry.name])
    (entry / "meta.json").write_text("{broken")
    failures = lint_store(store_dir)
    assert any(d.check == "store.meta" for d in failures[entry.name])


def test_lint_cli_exit_codes(tmp_path, capsys):
    store_dir = _build_store(tmp_path, n=1)
    assert analysis.main([str(store_dir)]) == 0
    out = capsys.readouterr().out
    assert "1 ok, 0 failed" in out

    entry = next(p for p in store_dir.iterdir() if p.is_dir())
    meta = json.loads((entry / "meta.json").read_text())
    meta["cost"]["total"] += 1
    (entry / "meta.json").write_text(json.dumps(meta))
    assert analysis.main([str(store_dir)]) == 1
    out = capsys.readouterr().out
    assert "FAIL" in out and "store.cost" in out

    assert analysis.main([str(tmp_path / "nowhere")]) == 2


# ---------------------------------------------------------------------------
# Diagnostics surface
# ---------------------------------------------------------------------------

def test_diagnostic_rows_and_error_rendering():
    d = Diagnostic(check="structure.topo-order", message="m", node=3,
                   stage="zeros")
    assert "structure.topo-order" in d.row()
    assert "zeros" in d.row() and "3" in d.row()
    err = VerificationError([d, d])
    assert "2 invariant violation" in str(err)
    assert err.diagnostics == (d, d)


def test_public_exports():
    for name in ("Diagnostic", "RangeAnalysis", "StackReport",
                 "VerificationError", "analyze_ranges", "diagnose_stack",
                 "verify_circuit", "verify_plan"):
        assert hasattr(netgen, name)
