"""Serving engine + LM quantization (paper technique at LM scale) tests."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import make_batch
from repro.models import api, base
from repro.quantized import apply as qapply
from repro.serve.engine import Engine, ServeConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.smoke("llama3.2-3b")
    params = base.tree_init(api.abstract_params(cfg), jax.random.PRNGKey(2))
    return cfg, params


def test_engine_generates(small_model):
    cfg, params = small_model
    eng = Engine(cfg, params, ServeConfig(max_len=64, max_new_tokens=8))
    prompts = np.arange(12, dtype=np.int32).reshape(3, 4) % cfg.vocab
    out = eng.generate(prompts)
    assert out.shape == (3, 8)
    assert out.dtype == np.int32
    assert (out >= 0).all() and (out < cfg.vocab).all()


def test_engine_matches_teacher_forcing(small_model):
    """Greedy engine output == greedy argmax under teacher forcing with the
    engine's own continuation (KV-cache path equals full forward)."""
    cfg, params = small_model
    eng = Engine(cfg, params, ServeConfig(max_len=64, max_new_tokens=4))
    prompts = (np.arange(8, dtype=np.int32).reshape(2, 4) * 7) % cfg.vocab
    gen = eng.generate(prompts)
    seq = np.concatenate([prompts, gen], axis=1)
    logits, _ = api.forward(cfg, params, {"tokens": jnp.asarray(seq)})
    # position P+i-1 predicts token P+i
    P = prompts.shape[1]
    for i in range(gen.shape[1]):
        want = np.asarray(jnp.argmax(logits[:, P + i - 1, :], -1))
        np.testing.assert_array_equal(gen[:, i], want)


def test_quantize_tree_roundtrip_and_compression(small_model):
    cfg, params = small_model
    qt, stats = qapply.quantize_tree(params, min_size=0)
    assert stats["n_quantized"] >= 3
    assert stats["compression"] > 2.0, stats      # fp32 -> int8 ~ 4x on weights
    deq = qapply.dequantize_tree(qt)
    # quantization error per channel bounded by scale/2
    flat_q = jax.tree_util.tree_flatten_with_path(qt)[0]
    for (path, orig), (_, back) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(deq)[0]):
        err = np.abs(np.asarray(orig, np.float32) - np.asarray(back, np.float32))
        assert err.max() <= np.abs(np.asarray(orig)).max() / 127.0 + 1e-6


def test_quantized_lm_quality_close(small_model):
    """Paper §III.C at LM scale: int8 weights barely move the loss."""
    cfg, params = small_model
    shape = base.ShapeConfig("smoke", 32, 4, "train")
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, shape, 0, seed=3).items()}
    loss_fp, _ = api.loss_fn(cfg, params, batch)
    qt, _ = qapply.quantize_tree(params, min_size=0)
    loss_q, _ = api.loss_fn(cfg, qapply.dequantize_tree(qt), batch)
    rel = abs(float(loss_q) - float(loss_fp)) / float(loss_fp)
    assert rel < 0.05, (float(loss_fp), float(loss_q))


def test_prune_stats(small_model):
    cfg, params = small_model
    st = qapply.prune_stats(params)
    assert st["total_channels"] > 0
    assert 0 <= st["dead_fraction"] < 0.5
