"""Additional layer-level correctness tests: rotary embeddings vs naive
references, norms, and W8-specialized serving equivalence."""
import numpy as np
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep (requirements.txt); stub keeps suite collectable
    from _hypothesis_stub import given, settings, strategies as st

from repro.layers import norms, rotary


def _naive_rope(x, positions, theta):
    """Literal per-element RoPE reference."""
    B, S, H, hd = x.shape
    half = hd // 2
    out = np.array(x, np.float32)
    for b in range(B):
        for s in range(S):
            pos = float(positions[b, s])
            for i in range(half):
                freq = 1.0 / (theta ** (i / half))
                ang = pos * freq
                c, sn = np.cos(ang), np.sin(ang)
                x1 = np.array(x[b, s, :, i], np.float32)
                x2 = np.array(x[b, s, :, i + half], np.float32)
                out[b, s, :, i] = x1 * c - x2 * sn
                out[b, s, :, i + half] = x2 * c + x1 * sn
    return out


def test_rope_matches_naive():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2, 5, 3, 8)).astype(np.float32)
    pos = rng.integers(0, 100, size=(2, 5)).astype(np.int32)
    got = rotary.rope(jnp.asarray(x), jnp.asarray(pos), theta=10_000.0)
    want = _naive_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_rope_relative_property():
    """RoPE inner products depend only on relative position."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)).astype(np.float32))

    def score(pq, pk):
        qr = rotary.rope(q, jnp.asarray([[pq]], jnp.int32), 1e4)
        kr = rotary.rope(k, jnp.asarray([[pk]], jnp.int32), 1e4)
        return float(jnp.sum(qr * kr))

    assert abs(score(7, 3) - score(14, 10)) < 1e-4      # same delta = 4
    assert abs(score(7, 3) - score(8, 3)) > 1e-6        # different delta


def test_mrope_reduces_to_rope_when_positions_equal():
    """With identical t/h/w position streams, M-RoPE == RoPE."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 6, 4, 16)).astype(np.float32))
    pos = jnp.asarray(rng.integers(0, 50, size=(2, 6)).astype(np.int32))
    pos3 = jnp.stack([pos] * 3)
    got = rotary.mrope(x, pos3, 1e4, sections=(3, 3, 2))
    want = rotary.rope(x, pos, 1e4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_sinusoidal_shapes_and_range():
    emb = rotary.sinusoidal_embedding(
        jnp.arange(8, dtype=jnp.int32)[None], 32)
    assert emb.shape == (1, 8, 32)
    assert float(jnp.max(jnp.abs(emb))) <= 1.0 + 1e-6


@settings(max_examples=15, deadline=None)
@given(d=st.sampled_from([8, 32, 64]), seed=st.integers(0, 10_000))
def test_rmsnorm_property_unit_rms(d, seed):
    """Post-norm RMS (with unit scale) is ~1 for any input."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(3, d)).astype(np.float32) * 10)
    p = {"scale": jnp.ones((d,))}
    y = norms.apply_norm("rmsnorm", p, x, eps=1e-6)
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_layernorm_matches_numpy():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(4, 16)).astype(np.float32)
    p = {"scale": jnp.full((16,), 2.0), "bias": jnp.full((16,), 0.5)}
    got = norms.apply_norm("layernorm", p, jnp.asarray(x), eps=1e-5)
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    want = (x - mu) / np.sqrt(var + 1e-5) * 2.0 + 0.5
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
