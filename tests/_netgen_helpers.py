"""Shared random-net / image generators for the netgen test modules.

One implementation (test modules bind their own bounds and salts as
one-line wrappers) so a change to input generation — e.g. covering
threshold edge values — reaches every netgen suite at once.
"""
from __future__ import annotations

import numpy as np

from repro.core import quantize


def random_net(seed: int, sizes, lo: int = -9, hi: int = 9):
    """A QuantizedNet with integer weights uniform in [lo, hi]."""
    rng = np.random.default_rng(seed)
    return quantize.QuantizedNet(weights=[
        rng.integers(lo, hi + 1, size=s).astype(np.int32)
        for s in zip(sizes, sizes[1:])])


def images(seed: int, b: int, n_in: int, salt: int = 99) -> np.ndarray:
    """A (b, n_in) uint8 image batch; `salt` decorrelates from the net."""
    return np.random.default_rng(seed + salt).integers(
        0, 256, size=(b, n_in)).astype(np.uint8)
