"""Backend-differential property harness.

One random quantized net, every execution path, bit-exact agreement:
the dense reference (`quantize.predict_quantized`), the IR interpreter
(`graph.evaluate` — the Verilog reference semantics, in both its strict
and MSB step variants), the compiled jnp / pallas / fused backends, and
the NetServer's stacked multi-net dispatch must all tell the same story.

The strict/MSB comparison is the interesting one: the compiled backends
and the software ladder fire the step on `acc > 0`, the emitted Verilog's
§V.D MSB trick on `acc >= 0`. The differential property is that the two
interpreters may disagree ONLY on inputs where some hidden accumulator
is exactly zero — anywhere else, every path is identical.

Runs under real `hypothesis` when installed, else the deterministic
fallback in `tests/_hypothesis_stub.py`.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import quantize
from repro import netgen

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep (requirements.txt); stub keeps suite collectable
    from _hypothesis_stub import given, settings, strategies as st

from _netgen_helpers import images, random_net


def _random_net(seed: int, sizes, lo=-5, hi=5):
    return random_net(seed, sizes, lo=lo, hi=hi)


def _images(seed: int, b: int, n_in: int) -> np.ndarray:
    return images(seed, b, n_in, salt=123)


def _rows_with_zero_hidden_acc(net, x: np.ndarray) -> np.ndarray:
    """Boolean (B,) mask: some *hidden* accumulator is exactly 0 (the only
    place the strict and MSB step semantics can diverge; the final layer
    feeds the argmax directly, with no step)."""
    a = (x.astype(np.int64) > net.input_threshold).astype(np.int64)
    any_zero = np.zeros(x.shape[0], dtype=bool)
    for w in net.weights[:-1]:
        acc = a @ np.asarray(w, np.int64)
        if acc.shape[1]:
            any_zero |= (acc == 0).any(axis=1)
        a = (acc > 0).astype(np.int64)
    return any_zero


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_in=st.integers(2, 18),
       n_h=st.integers(1, 10), n_out=st.integers(2, 6),
       depth3=st.booleans())
def test_backend_differential_bit_exact(seed, n_in, n_h, n_out, depth3):
    sizes = (n_in, n_h, n_h, n_out) if depth3 else (n_in, n_h, n_out)
    net = _random_net(seed, sizes)
    x = _images(seed, 12, n_in)
    ref = np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))

    # interpreter, unoptimized and optimized circuits, strict semantics
    c0 = netgen.lower(net)
    strict = netgen.evaluate(c0, x, step_semantics="strict")
    np.testing.assert_array_equal(strict, ref)
    copt, _ = netgen.run_pipeline(c0)
    np.testing.assert_array_equal(
        netgen.evaluate(copt, x, check_widths=True), ref)

    # every compiled backend (fused is 2-layer only by contract)
    backends = ("jnp", "pallas") + (() if depth3 else ("fused",))
    for backend in backends:
        got = np.asarray(
            netgen.specialize(net, backend=backend)(jnp.asarray(x)))
        np.testing.assert_array_equal(got, ref, err_msg=backend)

    # the Verilog reference semantics: MSB step may diverge from strict
    # only where a hidden accumulator is exactly zero
    msb = netgen.evaluate(c0, x, step_semantics="msb")
    clean = ~_rows_with_zero_hidden_acc(net, x)
    np.testing.assert_array_equal(msb[clean], strict[clean])
    if not np.array_equal(msb, strict):
        assert _rows_with_zero_hidden_acc(net, x)[msb != strict].all()


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_in=st.integers(4, 14),
       n_h=st.integers(2, 8), n_out=st.integers(2, 5))
def test_stacked_dispatch_differential(seed, n_in, n_h, n_out):
    """The multi-net stacked dispatch is just another backend: for random
    same-topology version pairs it must match each version's individual
    compiled predictor and the dense reference."""
    sizes = (n_in, n_h, n_out)
    nets = {"a": _random_net(seed, sizes), "b": _random_net(seed + 1, sizes)}
    x = _images(seed, 8, n_in)
    server = netgen.NetServer(slot_capacity=8, warmup=False)
    for name, net in nets.items():
        server.register(name, net)
    out = server.predict_many({"a": x, "b": x})
    assert server.dispatch_counts["stacked"] == 1
    for name, net in nets.items():
        ref = np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))
        np.testing.assert_array_equal(out[name], ref, err_msg=name)
        np.testing.assert_array_equal(
            out[name], np.asarray(server.compiled_for(name)(x)),
            err_msg=name)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_in=st.integers(2, 40),
       n_h=st.integers(1, 40), n_out=st.integers(2, 6),
       depth3=st.booleans())
def test_packed_datapath_differential(seed, n_in, n_h, n_out, depth3):
    """ISSUE 4/5/9 satellite: the four pallas datapaths — dense, the
    end-to-end bit-packed activation chain (`packed=true`), the fully
    bit-packed bit-plane chain (`planes=true`), and the whole-net
    megakernel (`fusednet=true`, one launch for the entire forward) —
    vs the dense reference, on random depths and widths that straddle
    the 32-lane boundary (fan_in padding, plane decomposition, and the
    megakernel's in-register repack must be exact, not approximately
    right)."""
    sizes = (n_in, n_h, n_h, n_out) if depth3 else (n_in, n_h, n_out)
    net = _random_net(seed, sizes)
    x = _images(seed, 10, n_in)
    ref = np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))
    for target in ("pallas", "pallas[packed=true]", "pallas[planes=true]",
                   "pallas[fusednet=true]"):
        fn = netgen.specialize(net, backend=target)
        np.testing.assert_array_equal(
            np.asarray(fn(jnp.asarray(x))), ref, err_msg=target)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_in=st.integers(2, 36),
       n_h=st.integers(1, 36), n_out=st.integers(2, 5),
       mag=st.integers(1, 40))
def test_planes_weight_range_differential(seed, n_in, n_h, n_out, mag):
    """ISSUE 5 satellite: the bit-plane decomposition is exact for any
    signed weight magnitude range — the plane count adapts to the
    layer's actual post-pass weights, including heavily negative ones."""
    net = _random_net(seed, (n_in, n_h, n_out), lo=-mag, hi=mag)
    x = _images(seed, 10, n_in)
    ref = np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))
    planes = netgen.specialize(net, backend="pallas[planes=true]")
    np.testing.assert_array_equal(np.asarray(planes(jnp.asarray(x))), ref)


def test_msb_divergence_is_reachable():
    """Sanity for the differential mask: a crafted zero accumulator makes
    strict and MSB genuinely disagree, and the mask flags that row."""
    w1 = np.array([[1], [-1]], np.int32)
    w2 = np.array([[0, 1]], np.int32)
    net = quantize.QuantizedNet(weights=[w1, w2])
    x = np.array([[255, 255], [255, 0]], np.uint8)
    c = netgen.lower(net)
    strict = netgen.evaluate(c, x, step_semantics="strict")
    msb = netgen.evaluate(c, x, step_semantics="msb")
    mask = _rows_with_zero_hidden_acc(net, x)
    assert mask[0] and strict[0] != msb[0]
    assert strict[1] == msb[1]
